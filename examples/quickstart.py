"""Quickstart: the ``repro.ot`` façade in five minutes.

Declare a Problem, compile an Executor, and solve the paper's synthetic
domain-adaptation task — solo, as a fused batch, and as a round-step
stream — showing the Theorem-2 equality (dense == screened, bitwise) and
the structured (group-sparse) transportation plan along the way.

Run:  PYTHONPATH=src python examples/quickstart.py

This example is executed in CI (smoke step), so the headline API shown
here can never silently rot.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import repro.ot as ot
from repro.core import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def main():
    # the paper's synthetic: L classes at (l*5, -5) [source] / (l*5, +5) [target]
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=8, samples_per_class=10, seed=0)
    )
    reg = GroupSparseReg.from_rho(1.0, 0.6)

    print("=== 1. Declare a problem, solve it (screened backend) ===")
    problem = ot.Problem.from_samples(Xs, ys, Xt, reg=reg)
    sol = ot.solve(problem)
    print(f"dual objective        : {sol.value:.6f}")
    print(f"transport cost <T,C>  : {sol.distance:.6f}")
    print(f"group sparsity        : {sol.group_sparsity:.1%} of (class,target) "
          "blocks are exactly zero")
    print(f"L-BFGS iterations     : {sol.iterations} "
          f"(skipped blocks: {sol.stats['zero']})")

    print("\n=== 2. Theorem 2: the dense (unscreened) backend matches ===")
    sol_dense = ot.solve(problem, ot.ExecutionPlan(grad_impl="dense"))
    print(f"dense objective       : {sol_dense.value:.6f}")
    print(f"identical             : {sol.value == sol_dense.value}")

    print("\n=== 3. Materialization-free geometry: no (m, n) cost in HBM ===")
    # sample-mode problems can skip the dense cost entirely: the Pallas
    # kernels rebuild each cost tile from the samples via
    # |x|^2 + |y|^2 - 2<x, y>  (docs/geometry.md).  The route is bitwise-
    # equal to the dense route run on the SAME factorized-recipe cost —
    # problem.materialized() — for a fixed backend.
    plan_otf = ot.ExecutionPlan(grad_impl="pallas", geometry="on_the_fly")
    sol_otf = ot.solve(problem, plan_otf)
    sol_mat = ot.solve(
        problem.materialized(), ot.ExecutionPlan(grad_impl="pallas", geometry="dense")
    )
    assert sol_otf.value == sol_mat.value, "on-the-fly != materialized-dense ?!"
    geom = ot.SquaredL2Geometry.from_samples(
        Xs, ys, Xt, problem.group_spec(), normalize_cost=True
    )
    dense_bytes = geom.rows * geom.cols * 4
    print(f"on-the-fly objective  : {sol_otf.value:.6f} "
          f"(== dense route on problem.materialized(), bitwise)")
    print(f"cost operand bytes    : dense {dense_bytes:,} -> "
          f"factorized {geom.hbm_bytes():,}")

    print("\n=== 4. A reusable executor: B problems, ONE fused program ===")
    problems = [problem] + [
        ot.Problem.from_samples(
            Xs, ys,
            make_domain_pair(
                DomainPairConfig(num_classes=8, samples_per_class=10, seed=s)
            )[2],
            reg=reg,
        )
        for s in range(1, 4)
    ]
    ex = ot.compile(problem)
    sols = ex.solve_many(problems)
    assert sols[0].value == sol.value, "batched != solo ?!"
    print(f"solved {len(sols)} problems in {ex.stats()['launches']} launch(es); "
          "problem 0 == solo solve, bitwise")
    print(f"objectives            : {[round(s.value, 6) for s in sols]}")

    print("\n=== 5. Round-step streaming (the serving engine's tick) ===")
    stream = ot.compile(problem).stream(problems)
    for info in stream:
        print(f"round {info['round']:2d}: {info['alive']} problem(s) still solving")
    assert [s.value for s in stream.solutions()] == [s.value for s in sols]
    print("stream result == fused batch, bitwise")

    print("\n=== 6. Diagnostics ===")
    print(ex.describe(sols[0]))


if __name__ == "__main__":
    main()
