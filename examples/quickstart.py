"""Quickstart: group-sparse regularized OT with safe screening.

Solves the paper's synthetic transportation problem three ways —
original dense method, screened JAX solver (Algorithm 1), and the faithful
CPU fast path — and shows the Theorem-2 equality plus the structured
(group-sparse) transportation plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (
    GroupSparseReg,
    group_sparsity,
    solve_groupsparse_ot,
    spec_from_labels,
    squared_euclidean_cost,
)
from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.solver import SolveOptions
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def main():
    # the paper's synthetic: L classes at (l*5, -5) [source] / (l*5, +5) [target]
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=8, samples_per_class=10, seed=0)
    )

    print("=== JAX screened solver (grad_impl='screened') ===")
    sol = solve_groupsparse_ot(Xs, ys, Xt, gamma=1.0, rho=0.6)
    print(f"dual objective        : {sol.value:.6f}")
    print(f"transport cost <T,C>  : {sol.distance:.6f}")
    print(f"group sparsity        : {group_sparsity(sol, ys):.1%} of (class,target) blocks are exactly zero")
    print(f"L-BFGS iterations     : {sol.result.iterations} "
          f"(skipped blocks: {sol.result.stats['zero']})")

    print("\n=== Theorem 2 check: dense == screened ===")
    sol_dense = solve_groupsparse_ot(
        Xs, ys, Xt, gamma=1.0, rho=0.6, opts=SolveOptions(grad_impl="dense")
    )
    print(f"dense objective       : {sol_dense.value:.6f}")
    print(f"identical             : {abs(sol.value - sol_dense.value) < 1e-6}")

    print("\n=== CPU wall-clock: origin vs Algorithm 1 (|L|=40, m=n=400) ===")
    # screening pays off with scale (paper Fig. 2): use a bigger instance
    Xs, ys, Xt, _ = make_domain_pair(
        DomainPairConfig(num_classes=40, samples_per_class=10, seed=0)
    )
    C = squared_euclidean_cost(Xs, Xt)
    C /= C.max()
    spec = spec_from_labels(ys, pad_to=8)
    m = n = len(ys)
    C_pad = G.pad_cost_matrix(C, ys, spec)
    a = G.pad_marginal(np.full(m, 1 / m), ys, spec)
    b = np.full(n, 1 / n)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    r0 = origin_solve(C_pad, a, b, spec, reg)
    r1 = fast_solve(C_pad, a, b, spec, reg)
    print(f"origin: {r0.wall_time:.3f}s   fast: {r1.wall_time:.3f}s   "
          f"gain: {r0.wall_time / r1.wall_time:.2f}x   "
          f"values match: {abs(r0.value - r1.value) < 1e-9}")


if __name__ == "__main__":
    main()
