"""End-to-end driver: train a ~135M-parameter LM (smollm-135m) with the
paper's group-sparse OT domain-alignment auxiliary loss.

Full run (a few hundred steps on the real config — the assignment's e2e
driver; several hours on this CPU container):

  PYTHONPATH=src python examples/train_lm_ot.py --steps 300

Quick smoke (reduced model, ~2 min):

  PYTHONPATH=src python examples/train_lm_ot.py --quick

Demonstrates: deterministic data pipeline, AdamW + cosine schedule, remat,
crash-safe checkpointing (kill it mid-run and re-launch: it resumes), the
straggler watchdog, and the OT alignment loss solved with Algorithm 1.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ot_ckpt")
    ap.add_argument("--no-ot", action="store_true")
    ap.add_argument("--dtype", default="float32",
                    help="param/compute dtype; float32 avoids slow bf16 "
                         "emulation on CPU (bf16 is the TPU deployment dtype)")
    args = ap.parse_args()

    import dataclasses

    cfg = get_config("smollm-135m")
    cfg = dataclasses.replace(cfg, param_dtype=args.dtype, compute_dtype=args.dtype)
    steps = args.steps
    if args.quick:
        cfg = cfg.reduced(num_layers=4, d_model=128, d_ff=256, vocab_size=1024)
        steps = min(steps, 40)

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=6e-4, warmup_steps=max(steps // 10, 5),
                                  decay_steps=steps),
        steps=steps,
        log_every=max(steps // 20, 1),
        checkpoint_every=max(steps // 4, 10),
        ot_align=not args.no_ot,
        ot_align_weight=0.05,
    )
    data = SyntheticLM(
        SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, num_classes=8)
    )
    trainer = Trainer(cfg, tcfg, data, ckpt_dir=args.ckpt)
    final = trainer.run()
    first = trainer.metrics_history[0] if trainer.metrics_history else {}
    print(f"\nce: {first.get('ce', float('nan')):.4f} -> {final.get('ce', float('nan')):.4f}"
          f"   (ot_distance: {final.get('ot_distance', 'n/a')})")


if __name__ == "__main__":
    main()
