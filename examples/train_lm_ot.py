"""End-to-end driver: train a ~135M-parameter LM (smollm-135m) with the
paper's group-sparse OT domain-alignment auxiliary loss.

The OT loss routes through the differentiable ``repro.ot.OTLayer`` façade
(exact Danskin gradients through the screened dual; docs/training.md), so
``--ot-solver stochastic`` swaps in the minibatch dual-ascent solver without
touching the training loop.

Full run (a few hundred steps on the real config — the assignment's e2e
driver; several hours on this CPU container):

  PYTHONPATH=src python examples/train_lm_ot.py --steps 300

Quick run (reduced model, ~2 min):

  PYTHONPATH=src python examples/train_lm_ot.py --quick

CI smoke (tiny model, a few steps; exits non-zero unless the training loss
strictly decreases):

  PYTHONPATH=src python examples/train_lm_ot.py --smoke

Demonstrates: deterministic data pipeline, AdamW + cosine schedule, remat,
crash-safe checkpointing (kill it mid-run and re-launch: it resumes), the
straggler watchdog, and the OT alignment loss solved with Algorithm 1.
"""
import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, few steps; exit 1 unless loss decreases")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ot_ckpt")
    ap.add_argument("--no-ot", action="store_true")
    ap.add_argument("--ot-solver", default="lbfgs",
                    choices=("lbfgs", "stochastic"),
                    help="dual solver for the OT alignment loss")
    ap.add_argument("--ot-grad-impl", default="screened",
                    choices=("dense", "screened", "pallas", "fused"),
                    help="gradient-oracle backend for the OT alignment loss")
    ap.add_argument("--dtype", default="float32",
                    help="param/compute dtype; float32 avoids slow bf16 "
                         "emulation on CPU (bf16 is the TPU deployment dtype)")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    cfg = dataclasses.replace(cfg, param_dtype=args.dtype, compute_dtype=args.dtype)
    steps = args.steps
    if args.smoke:
        cfg = cfg.reduced(num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        steps = min(steps, 8)
        args.batch, args.seq = 4, 32
    elif args.quick:
        cfg = cfg.reduced(num_layers=4, d_model=128, d_ff=256, vocab_size=1024)
        steps = min(steps, 40)

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=1e-3 if args.smoke else 6e-4,
                                  warmup_steps=max(steps // 10, 2 if args.smoke else 5),
                                  decay_steps=steps),
        steps=steps,
        log_every=1 if args.smoke else max(steps // 20, 1),
        checkpoint_every=max(steps // 4, 10),
        ot_align=not args.no_ot,
        ot_align_weight=0.05,
        ot_solver=args.ot_solver,
        ot_grad_impl=args.ot_grad_impl,
    )
    data = SyntheticLM(
        SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, num_classes=8)
    )
    ckpt_dir = None if args.smoke else args.ckpt
    trainer = Trainer(cfg, tcfg, data, ckpt_dir=ckpt_dir)
    final = trainer.run()
    first = trainer.metrics_history[0] if trainer.metrics_history else {}
    print(f"\nce: {first.get('ce', float('nan')):.4f} -> {final.get('ce', float('nan')):.4f}"
          f"   (ot_distance: {final.get('ot_distance', 'n/a')})")

    if args.smoke:
        ok = final.get("loss", float("inf")) < first.get("loss", float("-inf"))
        print(f"smoke: loss {first.get('loss'):.4f} -> {final.get('loss'):.4f} "
              f"({'DECREASED' if ok else 'DID NOT DECREASE'})")
        if not ok:
            sys.exit(1)


if __name__ == "__main__":
    main()
