"""Tutorial: group-sparse OT for domain adaptation — solo, batched, sharded.

A narrated, runnable walkthrough of the whole stack on the paper's task
(unsupervised domain adaptation): source samples are labeled, target
samples are not, and the group-sparse transport plan moves class-coherent
mass so each target point can be labeled by the class that sends it the
most mass.  Everything runs through the ``repro.ot`` façade — one
declarative Problem, one compiled Executor — climbing the three
execution tiers:

  1. SOLO     one problem, one program        (Executor.solve)
  2. BATCHED  B problems, ONE program         (Executor.solve_many)
  3. SHARDED  B problems over all devices     (Executor.solve_many + mesh)

and verifies at each step that the answer is *bitwise* the same — the
batch axis and the device mesh are performance structure, never numerics.

Run:  PYTHONPATH=src python examples/domain_adaptation.py [--classes 5]

On a CPU-only machine we force 4 virtual host devices (before jax
initializes) so stage 3 genuinely shards; on a real multi-device host the
flag is unnecessary and left untouched.  Docs: docs/architecture.md for
the map of the layers used here.
"""
import argparse
import os
import sys
import time
from pathlib import Path

# stage 3 wants >1 device; the host-platform override must be set before
# jax is imported (harmless if XLA_FLAGS is already configured)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

import repro.ot as ot
from repro.core import sinkhorn_log, squared_euclidean_cost
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def predict_from_plan(T: np.ndarray, y_src: np.ndarray, L: int) -> np.ndarray:
    """Target label = class with max incoming transported mass."""
    mass = np.zeros((L, T.shape[1]))
    for lbl in range(L):
        mass[lbl] = T[y_src == lbl].sum(axis=0)
    return mass.argmax(axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--per-class", type=int, default=10)
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--domains", type=int, default=8,
                    help="target domains for the batched/sharded stages")
    args = ap.parse_args()
    L = args.classes

    # ----------------------------------------------------------------- setup
    # One labeled source domain and `--domains` unlabeled target domains
    # (independent draws of the same shifted-cluster generator) — the
    # serving scenario: many concurrent adaptation problems, same geometry.
    print("=" * 72)
    print("SETUP: synthetic unsupervised domain adaptation")
    print("=" * 72)
    Xs, ys, Xt0, yt0 = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=args.per_class,
                         dim=args.dim, shift=3.0, seed=0)
    )
    targets = [(Xt0, yt0)]
    for s in range(1, args.domains):
        targets.append(make_domain_pair(
            DomainPairConfig(num_classes=L, samples_per_class=args.per_class,
                             dim=args.dim, shift=3.0, seed=s)
        )[2:])
    m, n = len(ys), len(targets[0][0])

    # ONE declarative problem per target domain; the regularizer, group
    # layout and execution policy live in the Problem / ExecutionPlan
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    problems = [
        ot.Problem.from_samples(Xs, ys, Xt, reg=reg, pad_to=8)
        for Xt, _ in targets
    ]
    plan = ot.ExecutionPlan(grad_impl="screened", max_iters=150)
    ex = ot.compile(problems[0], plan)
    print(f"source: {m} samples, {L} classes; "
          f"targets: {len(targets)} domains x {n} samples")
    print(ex.describe())

    # ------------------------------------------------------------ 1. solo
    # One problem end to end, plus the entropic baseline for accuracy
    # context: group structure is what transports class-coherent mass.
    print()
    print("=" * 72)
    print("STAGE 1 — SOLO: one problem, one program")
    print("=" * 72)
    t0 = time.perf_counter()
    sol = ex.solve(problems[0])
    t_solo = time.perf_counter() - t0
    acc_gs = float((predict_from_plan(sol.plan, ys, L) == yt0).mean())

    C0 = squared_euclidean_cost(Xs, Xt0)
    C0 /= C0.max()
    sk = sinkhorn_log(jnp.asarray(C0, jnp.float32), jnp.full((m,), 1 / m),
                      jnp.full((n,), 1 / n), eps=0.01)
    acc_sk = float((predict_from_plan(np.asarray(sk.plan), ys, L) == yt0).mean())
    print(f"group-sparse OT:  accuracy {acc_gs:.1%}  "
          f"value {sol.value:.6f}  ({t_solo:.2f}s incl. jit)")
    print(f"entropic OT:      accuracy {acc_sk:.1%}  (no group structure)")

    # ---------------------------------------------------------- 2. batched
    # All target domains at once: solve_many stacks every problem behind a
    # leading B axis and the whole batch advances in ONE jitted program
    # (masked per-problem convergence — no recompiles, no Python loop).
    print()
    print("=" * 72)
    print(f"STAGE 2 — BATCHED: {len(targets)} problems, ONE program")
    print("=" * 72)
    launches_before = ex.stats()["launches"]
    t0 = time.perf_counter()
    sols = ex.solve_many(problems)
    t_batch = time.perf_counter() - t0
    print(f"solved {len(sols)} problems in {t_batch:.2f}s (incl. jit) with "
          f"{ex.stats()['launches'] - launches_before} program launch(es)")
    print(f"per-problem rounds: {[s.rounds for s in sols]}")
    # the batch axis is invisible to numerics: problem 0 solved inside the
    # batch equals the solo solve of stage 1 bit for bit
    assert sols[0].value == sol.value, "batched != solo ?!"
    print("bitwise check: batched problem 0 == solo solve        OK")

    # ---------------------------------------------------------- 3. sharded
    # Same batch, problem axis split over every local device: attach a
    # mesh (ExecutionPlan(devices='all')) and solve_many dispatches to the
    # shard_map program — each device runs the stage-2 solver on its slice
    # (its own screening state, its own compact tile schedules), no
    # collectives inside a round.  Still one program launch.
    print()
    print("=" * 72)
    print(f"STAGE 3 — SHARDED: {len(targets)} problems over "
          f"{jax.local_device_count()} devices")
    print("=" * 72)
    exs = ot.compile(problems[0], ot.ExecutionPlan(
        grad_impl="screened", max_iters=150, devices="all"
    ))
    mesh = exs.mesh
    t0 = time.perf_counter()
    sols_sh = exs.solve_many(problems)
    t_shard = time.perf_counter() - t0
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} -> "
          f"{mesh.size} x {len(targets) // mesh.size} problems/device, "
          f"{exs.stats()['launches']} launch(es), {t_shard:.2f}s (incl. jit)")
    # the mesh is invisible too: every problem bitwise-equals stage 2
    same = all(
        bool(jnp.all(a.result.lbfgs_state.x == b.result.lbfgs_state.x))
        for a, b in zip(sols_sh, sols)
    )
    assert same, "sharded != batched ?!"
    print("bitwise check: sharded == batched (all problems)      OK")

    # label all target domains — Solution.plan is already un-padded back
    # to the caller's row order, so prediction is a one-liner per domain
    accs = [
        float((predict_from_plan(s.plan, ys, L) == yt).mean())
        for s, (_, yt) in zip(sols_sh, targets)
    ]
    print(f"target-domain accuracies: "
          f"{', '.join(f'{a:.1%}' for a in accs)}")
    print()
    print("Next: stream mixed-shape problems through the serving engine "
          "(docs/serving.md) — it runs stage 3 continuously.")


if __name__ == "__main__":
    main()
