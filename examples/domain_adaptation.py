"""Unsupervised domain adaptation with group-sparse OT (the paper's task).

Source samples are labeled, target samples are not.  The group-sparse plan
transports class-coherent mass; target labels are predicted by the class
that sends each target the most mass.  Compares accuracy + wall-clock vs
(a) the unregularized-structure entropic OT baseline (Cuturi 2013) and
(b) the original (unscreened) group-sparse method.

Run:  PYTHONPATH=src python examples/domain_adaptation.py [--classes 10]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import sinkhorn_log, solve_groupsparse_ot, squared_euclidean_cost
from repro.core import groups as G
from repro.core.cpu_baseline import fast_solve, origin_solve
from repro.core.regularizers import GroupSparseReg
from repro.data.pipeline import DomainPairConfig, make_domain_pair


def predict_from_plan(T: np.ndarray, y_src: np.ndarray, L: int) -> np.ndarray:
    """Target label = class with max incoming mass."""
    mass = np.zeros((L, T.shape[1]))
    for l in range(L):
        mass[l] = T[y_src == l].sum(axis=0)
    return mass.argmax(axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--per-class", type=int, default=15)
    ap.add_argument("--dim", type=int, default=8)
    args = ap.parse_args()
    L = args.classes

    Xs, ys, Xt, yt = make_domain_pair(
        DomainPairConfig(num_classes=L, samples_per_class=args.per_class,
                         dim=args.dim, shift=3.0, seed=0)
    )

    # --- group-sparse OT (screened) ---
    t0 = time.perf_counter()
    sol = solve_groupsparse_ot(Xs, ys, Xt, gamma=1.0, rho=0.6)
    t_gs = time.perf_counter() - t0
    acc_gs = float((predict_from_plan(sol.plan, ys, L) == yt).mean())

    # --- entropic baseline ---
    C = squared_euclidean_cost(Xs, Xt)
    C /= C.max()
    m, n = C.shape
    t0 = time.perf_counter()
    sk = sinkhorn_log(jnp.asarray(C, jnp.float32), jnp.full((m,), 1 / m),
                      jnp.full((n,), 1 / n), eps=0.01)
    t_sk = time.perf_counter() - t0
    acc_sk = float((predict_from_plan(np.asarray(sk.plan), ys, L) == yt).mean())

    # --- origin vs fast wall clock on the same problem ---
    spec = G.spec_from_labels(ys, pad_to=8)
    C_pad = G.pad_cost_matrix(C, ys, spec)
    a = G.pad_marginal(np.full(m, 1 / m), ys, spec)
    b = np.full(n, 1 / n)
    reg = GroupSparseReg.from_rho(1.0, 0.6)
    r0 = origin_solve(C_pad, a, b, spec, reg)
    r1 = fast_solve(C_pad, a, b, spec, reg)

    print(f"target-label accuracy: group-sparse OT = {acc_gs:.1%}   "
          f"entropic OT = {acc_sk:.1%}")
    print(f"group-sparse solve: {t_gs:.2f}s (jit incl.)   sinkhorn: {t_sk:.2f}s")
    print(f"origin {r0.wall_time:.3f}s vs fast {r1.wall_time:.3f}s "
          f"-> gain {r0.wall_time / r1.wall_time:.2f}x, "
          f"objectives match: {abs(r0.value - r1.value) < 1e-9}")


if __name__ == "__main__":
    main()
