"""Serve a small model with batched requests (continuous batching engine).

More requests than slots: the engine admits, decodes per-slot positions in
one fused step, recycles slots as requests finish.

Run:  PYTHONPATH=src python examples/serve_lm.py [--requests 8]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch,
        max_len=args.prompt_len + args.new_tokens + 8,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  request {r.rid}: {r.out_tokens}")


if __name__ == "__main__":
    main()
