"""Intra-repo link checker for the docs suite.

  python tools/check_links.py [files...]

Scans markdown files (default: README.md + everything under docs/) for
``[text](target)`` links and fails on any *relative* target that does not
exist in the repo.  ``http(s)://`` / ``mailto:`` links are skipped (CI
must not flake on the network), as are bare ``#anchor`` self-references.
For ``path#anchor`` links the path must exist; the anchor is checked
against the target file's ATX headings when the target is markdown.

No third-party dependencies — runs in the CI docs job without jax.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
CODE_SPAN_RE = re.compile(r"`[^`\n]*`")


def _strip_code(text: str) -> str:
    """Drop fenced blocks and inline code spans — `foo[_bar](...)` in a
    code span is API notation, not a markdown link."""
    return CODE_SPAN_RE.sub("", FENCE_RE.sub("", text))


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dash spaces."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _anchors(md: Path) -> set:
    # strip fenced blocks first: '#'-prefixed code comments are not headings
    return {_slug(h)
            for h in HEADING_RE.findall(FENCE_RE.sub("", md.read_text()))}


def check_file(md: Path):
    """Yield one message per broken link in ``md``."""
    text = _strip_code(md.read_text())
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:                 # same-file anchor
            if anchor and _slug(anchor) not in _anchors(md):
                yield f"{md.relative_to(REPO)}: broken anchor #{anchor}"
            continue
        dest = (md.parent / path_part).resolve()
        if not dest.exists():
            yield f"{md.relative_to(REPO)}: broken link {target}"
            continue
        if anchor and dest.suffix == ".md":
            if _slug(anchor) not in _anchors(dest):
                yield (f"{md.relative_to(REPO)}: broken anchor "
                       f"{path_part}#{anchor}")


def main(argv) -> int:
    """Check the given files (or README + docs/); 0 = clean."""
    if argv:
        files = [REPO / f if not Path(f).is_absolute() else Path(f)
                 for f in argv]
    else:
        files = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    failures = []
    for f in files:
        failures.extend(check_file(f))
    for msg in failures:
        print(f"BROKEN LINK: {msg}")
    if failures:
        print(f"link gate: {len(failures)} broken link(s) "
              f"across {len(files)} file(s)")
        return 1
    print(f"link gate: clean ({len(files)} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
