"""Docstring-coverage gate for the public API surface.

  python tools/check_docstrings.py [files...]

Walks the AST (no imports — runs without jax installed, e.g. in the CI
docs job) and fails when any PUBLIC symbol — module, top-level class or
function, or public method of a public class — lacks a docstring.

Public = name not starting with '_'.  Dunder methods are exempt except
``__init__`` whose documentation we accept at the class level (NumPy
convention: parameters documented in the class docstring).

Default file set: the modules docs/api.md documents.  Keep the two lists
in sync — the link checker verifies docs/api.md's module links resolve,
and this gate verifies their contents are documented.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

DEFAULT_FILES = [
    "src/repro/ot/__init__.py",
    "src/repro/ot/problem.py",
    "src/repro/ot/plan.py",
    "src/repro/ot/geometry.py",
    "src/repro/ot/solution.py",
    "src/repro/ot/executor.py",
    "src/repro/ot/diff.py",
    "src/repro/core/stochastic.py",
    "src/repro/core/regularizers.py",
    "src/repro/core/solver.py",
    "src/repro/core/sharded.py",
    "src/repro/kernels/ops.py",
    "src/repro/serving/ot_engine.py",
    "src/repro/serving/policy.py",
    "src/repro/serving/traffic.py",
    "src/repro/utils/faults.py",
]


def _missing_in_class(cls: ast.ClassDef, path: str):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            name = node.name
            if name.startswith("_"):      # private + dunders (incl. __init__)
                continue
            if ast.get_docstring(node) is None:
                yield f"{path}:{node.lineno}: method {cls.name}.{name}"


def missing_docstrings(path: Path):
    """Yield one message per undocumented public symbol in ``path``."""
    rel = str(path.relative_to(REPO))
    tree = ast.parse(path.read_text())
    if ast.get_docstring(tree) is None:
        yield f"{rel}:1: module"
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                yield f"{rel}:{node.lineno}: function {node.name}"
        elif isinstance(node, ast.ClassDef):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                yield f"{rel}:{node.lineno}: class {node.name}"
            yield from _missing_in_class(node, rel)


def main(argv) -> int:
    """Check the given files (or the default API surface); 0 = clean."""
    files = [Path(f) for f in argv] or [REPO / f for f in DEFAULT_FILES]
    failures = []
    for f in files:
        if not f.is_absolute():
            f = REPO / f
        failures.extend(missing_docstrings(f))
    for msg in failures:
        print(f"MISSING DOCSTRING: {msg}")
    checked = ", ".join(str(f) for f in (argv or DEFAULT_FILES))
    if failures:
        print(f"docstring gate: {len(failures)} public symbol(s) "
              f"undocumented in [{checked}]")
        return 1
    print(f"docstring gate: clean ({checked})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
