"""Regenerate tests/fixtures/golden_diff.json (the diff-layer golden data).

The fixture pins the finite-difference reference gradients for
tests/test_diff_layer.py: central differences of the *float64* unscreened
reference solver (``repro.core.cpu_baseline.origin_solve``, maxiter 8000,
gtol 1e-12) on problems that regenerate exactly from their committed
(seed, L, g, n[, d]) coordinates — the fixture stores only coordinates,
probe indices and expected numbers, never arrays (the repo's golden-fixture
convention; see tests/conftest.py).

Two cases:

* ``dense``  — seed-0 uniform random cost, probes are (i, j) cost entries;
  FD step 1e-5.  ``jax.grad`` of :func:`repro.ot.ot_loss` must match these
  at every backend.
* ``samples`` — seed-3 Gaussian clouds under the normalized squared-l2
  geometry, probes are (i, k) source / (j, k) target coordinates; FD step
  1e-4.  The normalization scale is FROZEN at the unperturbed f64 value
  (``scale64``): the layer treats the chunked max as a constant of the
  backward pass (stop_gradient), so the FD reference must too — an FD
  reference that re-derives the max per perturbation measures a different
  (sub)gradient at the max-attaining entry.

Usage:  PYTHONPATH=src python tools/gen_golden_diff.py
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import groups as G                       # noqa: E402
from repro.core.cpu_baseline import origin_solve         # noqa: E402
from repro.core.regularizers import GroupSparseReg       # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "fixtures",
                   "golden_diff.json")

GAMMA, RHO = 1.0, 0.6
MAXITER, GTOL = 8000, 1e-12


def _solve64(C, a, b, spec, reg):
    return origin_solve(C.astype(np.float64), a.astype(np.float64),
                        b.astype(np.float64), spec, reg,
                        maxiter=MAXITER, gtol=GTOL)


def dense_case(seed=0, L=3, g=8, n=20, h=1e-5, num_probes=10):
    m_pad = L * g
    rng = np.random.default_rng(seed)
    C = rng.random((m_pad, n), dtype=np.float32).astype(np.float64)
    a = np.full(m_pad, 1.0 / m_pad)
    b = np.full(n, 1.0 / n)
    reg = GroupSparseReg.from_rho(GAMMA, RHO)
    spec = G.GroupSpec(num_groups=L, group_size=g, sizes=(g,) * L, m=m_pad)

    base = _solve64(C, a, b, spec, reg)
    prng = np.random.default_rng(7)
    probes = []
    for _ in range(num_probes):
        i, j = int(prng.integers(m_pad)), int(prng.integers(n))
        Cp, Cm = C.copy(), C.copy()
        Cp[i, j] += h
        Cm[i, j] -= h
        fd = (_solve64(Cp, a, b, spec, reg).value
              - _solve64(Cm, a, b, spec, reg).value) / (2 * h)
        probes.append([i, j, fd])
    return {
        "coords": {"seed": seed, "L": L, "g": g, "n": n},
        "gamma": GAMMA, "rho": RHO, "fd_step": h,
        "value_f64": base.value,
        "fd_probes": probes,
    }


def samples_case(seed=3, L=3, g=8, n=20, d=5, h=1e-4, num_probes=6):
    m_pad = L * g
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m_pad, d)).astype(np.float32).astype(np.float64)
    Y = rng.normal(size=(n, d)).astype(np.float32).astype(np.float64)
    a = np.full(m_pad, 1.0 / m_pad)
    b = np.full(n, 1.0 / n)
    reg = GroupSparseReg.from_rho(GAMMA, RHO)
    spec = G.GroupSpec(num_groups=L, group_size=g, sizes=(g,) * L, m=m_pad)

    C0 = ((X[:, None, :] - Y[None, :, :]) ** 2).sum(-1)
    scale64 = 1.0 / C0.max()                      # frozen, like the layer's

    def val(Xm, Ym):
        C = scale64 * ((Xm[:, None, :] - Ym[None, :, :]) ** 2).sum(-1)
        return _solve64(C, a, b, spec, reg).value

    prng = np.random.default_rng(7)
    fd_x, fd_y = [], []
    for _ in range(num_probes):
        i, k = int(prng.integers(m_pad)), int(prng.integers(d))
        Xp, Xm2 = X.copy(), X.copy()
        Xp[i, k] += h
        Xm2[i, k] -= h
        fd_x.append([i, k, (val(Xp, Y) - val(Xm2, Y)) / (2 * h)])
    for _ in range(num_probes):
        j, k = int(prng.integers(n)), int(prng.integers(d))
        Yp, Ym2 = Y.copy(), Y.copy()
        Yp[j, k] += h
        Ym2[j, k] -= h
        fd_y.append([j, k, (val(X, Yp) - val(X, Ym2)) / (2 * h)])
    return {
        "coords": {"seed": seed, "L": L, "g": g, "n": n, "d": d},
        "gamma": GAMMA, "rho": RHO, "fd_step": h,
        "scale64": scale64,
        "value_f64": val(X, Y),
        "fd_x_probes": fd_x,
        "fd_y_probes": fd_y,
    }


def main():
    data = {
        "schema_version": 1,
        "solver": {"maxiter": MAXITER, "gtol": GTOL},
        "dense": dense_case(),
        "samples": samples_case(),
    }
    with open(OUT, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.relpath(OUT)}")
    print(f"  dense   value_f64 = {data['dense']['value_f64']:.12f}")
    print(f"  samples value_f64 = {data['samples']['value_f64']:.12f}")


if __name__ == "__main__":
    main()
