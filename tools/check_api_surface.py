"""API-surface gate: ``repro.ot.__all__`` must match docs/api.md.

  python tools/check_api_surface.py

The façade's exported names are read from ``src/repro/ot/__init__.py`` by
AST (no imports — runs without jax installed, e.g. in the CI docs job) and
compared against the backticked symbols documented in the ``repro.ot``
section of docs/api.md.  A symbol exported but undocumented, or documented
but not exported, fails the gate — the docs page and the package can never
silently diverge.

Doc symbols are taken from the first backticked token of each table row in
the section (``| `Problem` | ... |``); call signatures are stripped
(`` `compile(problem, plan)` `` documents ``compile``).
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
INIT = REPO / "src" / "repro" / "ot" / "__init__.py"
DOCS = REPO / "docs" / "api.md"
SECTION = "repro.ot"


def exported_names(init_path: Path) -> set:
    """The ``__all__`` list of a package's ``__init__.py``, by AST."""
    tree = ast.parse(init_path.read_text())
    for node in tree.body:
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if "__all__" in targets:
                return {
                    elt.value
                    for elt in node.value.elts
                    if isinstance(elt, ast.Constant)
                }
    raise SystemExit(f"{init_path}: no literal __all__ found")


def documented_names(docs_path: Path, section: str) -> set:
    """Backticked lead symbols of the table rows in one api.md section."""
    text = docs_path.read_text()
    # the section runs from its heading to the next same-or-higher heading
    m = re.search(rf"^##[^\n]*`{re.escape(section)}`[^\n]*$", text, re.M)
    if m is None:
        raise SystemExit(f"{docs_path}: no '## ... `{section}` ...' section")
    body = text[m.end():]
    nxt = re.search(r"^## ", body, re.M)
    if nxt:
        body = body[: nxt.start()]
    names = set()
    for row in re.finditer(r"^\|\s*`([^`|]+)`", body, re.M):
        sym = row.group(1).strip()
        sym = sym.split("(")[0].split(".")[0].strip()
        if sym and sym != "symbol":
            names.add(sym)
    if not names:
        raise SystemExit(f"{docs_path}: section '{section}' documents no symbols")
    return names


def main() -> int:
    """Compare the two name sets; 0 = in sync."""
    exported = exported_names(INIT)
    documented = documented_names(DOCS, SECTION)
    missing_docs = sorted(exported - documented)
    missing_export = sorted(documented - exported)
    for name in missing_docs:
        print(f"UNDOCUMENTED: repro.ot.{name} is exported but absent from "
              f"docs/api.md '{SECTION}' section")
    for name in missing_export:
        print(f"UNEXPORTED: docs/api.md documents repro.ot.{name} but "
              f"__all__ does not export it")
    if missing_docs or missing_export:
        print(f"api-surface gate: {len(missing_docs) + len(missing_export)} "
              f"mismatch(es) between repro.ot.__all__ and docs/api.md")
        return 1
    print(f"api-surface gate: clean ({len(exported)} symbols in sync)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
